"""Roofline term assembly for every dry-run cell (EXPERIMENTS.md §Roofline).

Terms per (arch × shape) on the single-pod mesh (256 chips):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HBM_bytes_per_device / HBM_bw              [s]
  collective term = collective_bytes_per_device / link_bw      [s]

Sources:
  * FLOPs + collective bytes: the loop-trip-scaled HLO walk
    (repro.launch.hlo.walk_stats) over the compiled module saved by the
    dry-run — NOT raw cost_analysis, which counts scan bodies once
    (verified; see §Roofline methodology).  The SPMD module is per-device,
    so these are per-device quantities already.
  * HBM bytes: analytic traffic model (weights / optimizer / activations /
    attention scores / KV caches), mirroring the sharding rules' divisibility
    decisions — documented per-kind below.
  * MODEL_FLOPS = 6·N·D (train, dense) or 6·N_active·D (MoE); prefill uses
    2·N·D, decode 2·N·B per step.  The ratio MODEL_FLOPS / HLO_FLOPs_global
    surfaces remat/redundancy waste.

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import os

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


# ---------------------------------------------------------------------------------
# parameter counting
# ---------------------------------------------------------------------------------

def param_counts(cfg) -> tuple[int, int]:
    """(total params N, active params N_active)."""
    from repro.models import build_model

    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if cfg.moe and name in ("w_gate", "w_up", "w_down") and \
                len(leaf.shape) == 4:
            expert += n
    active = total
    if cfg.moe and expert:
        active = total - expert + expert * cfg.moe.top_k / cfg.moe.num_experts
    return total, int(active)


# ---------------------------------------------------------------------------------
# analytic HBM traffic model (per device)
# ---------------------------------------------------------------------------------

def _shards(n: int, axis: int) -> int:
    return axis if n % axis == 0 else 1


def hbm_bytes(cfg, shape, chips=(16, 16), accum: int = 4) -> float:
    """Per-device HBM bytes for one step of this cell (documented model)."""
    data_sh, model_sh = chips
    n_chips = data_sh * model_sh
    N, _ = param_counts(cfg)
    p_dev = N / n_chips                      # fully sharded (fsdp x tp) share
    B = shape.global_batch
    S = shape.seq_len
    b_loc = max(B // data_sh, 1)
    d = cfg.d_model
    L = cfg.n_layers + (cfg.dec_layers if cfg.is_encdec else 0)
    h_loc = max(cfg.n_heads // _shards(cfg.n_heads, model_sh), 1) \
        if cfg.n_heads % model_sh == 0 else cfg.n_heads  # replicated heads

    if shape.kind == "train":
        # optimizer: read+write p, m, v in fp32
        opt = 24.0 * p_dev
        # weights stream once per microbatch, fwd + bwd(x2) in bf16
        weights = 3.0 * accum * p_dev * 2
        # residual/activation traffic: ~6 passes over the token stream/layer
        act = 6.0 * L * b_loc * S * d * 2
        # materialized attention scores (XLA path, fp32, fwd+bwd+remat)
        scores = 0.0
        if cfg.attn_pattern == "all":
            scores = 3.0 * L * (b_loc / accum) * h_loc * _attn_area(cfg, S) \
                * 4 * accum
        elif cfg.attn_pattern == "griffin_1_2":
            scores = 3.0 * (L // 3) * (b_loc / accum) * h_loc \
                * _attn_area(cfg, S) * 4 * accum
        return opt + weights + act + scores

    if shape.kind == "prefill":
        weights = p_dev * 2
        act = 4.0 * L * b_loc * S * d * 2
        scores = 0.0
        if cfg.attn_pattern == "all":
            scores = 1.0 * L * b_loc * h_loc * _attn_area(cfg, S) * 4
        elif cfg.attn_pattern == "griffin_1_2":
            scores = 1.0 * (L // 3) * b_loc * h_loc * _attn_area(cfg, S) * 4
        return weights + act + scores

    # decode: weights once + cache read/write per token
    weights = p_dev * 2
    cache = _cache_bytes_per_device(cfg, shape, chips)
    return weights + 2.0 * cache / max(1, 1)  # read k+v (+small write)


def _attn_area(cfg, S: int) -> float:
    """Scores 'area' per head: S^2/2 causal, bounded by window when set."""
    w = cfg.swa_window or cfg.local_window
    if w and w < S:
        return S * w
    return S * S / 2


def _cache_bytes_per_device(cfg, shape, chips) -> float:
    from repro.configs.shapes import cache_capacity

    data_sh, model_sh = chips
    B = shape.global_batch
    S = shape.seq_len
    b_loc = max(B // data_sh, 1) if B % data_sh == 0 else B
    if cfg.attn_pattern == "rwkv":
        H = cfg.n_heads
        h_loc = H // _shards(H, model_sh)
        return cfg.n_layers * b_loc * h_loc * 64 * 64 * 4
    cap = cache_capacity(cfg, S)
    kv_loc = (cfg.n_kv // model_sh if cfg.n_kv % model_sh == 0
              else cfg.n_kv)
    seq_div = model_sh if (cfg.n_kv % model_sh and cap % model_sh == 0) else 1
    if cfg.n_kv % model_sh == 0:
        per_layer = b_loc * cap * kv_loc * cfg.hd * 2 * 2
    else:
        per_layer = b_loc * (cap / seq_div) * cfg.n_kv * cfg.hd * 2 * 2
    L_attn = cfg.n_layers
    extra = 0.0
    if cfg.attn_pattern == "griffin_1_2":
        L_attn = cfg.n_layers // 3
        # rg-lru h state + conv state
        r_loc = (cfg.rnn_width or cfg.d_model) / _shards(
            cfg.rnn_width or cfg.d_model, model_sh)
        extra = cfg.n_layers * b_loc * r_loc * 4 * 2
    if cfg.is_encdec:
        L_attn = cfg.dec_layers
        per_layer *= 2  # self cache + cross K/V
    return L_attn * per_layer + extra


# ---------------------------------------------------------------------------------
# cell assembly
# ---------------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    note: str = ""

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for k in ("compute_s", "memory_s", "collective_s"):
            d[k] = float(f"{d[k]:.3e}")
        d["useful_ratio"] = round(self.useful_ratio, 3)
        return d


def model_flops(cfg, shape) -> float:
    N, N_active = param_counts(cfg)
    n_eff = N_active if cfg.moe else N
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_eff * tokens
    if shape.kind == "prefill":
        return 2.0 * n_eff * tokens
    return 2.0 * n_eff * shape.global_batch      # per decode step


def load_cell(arch: str, shape_name: str, mesh: str = "single",
              dryrun_dir: str | None = None) -> dict | None:
    d = dryrun_dir or DRYRUN_DIR
    path = os.path.join(d, f"{arch}__{shape_name}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def cell_hlo_stats(arch: str, shape_name: str, mesh: str = "single",
                   dryrun_dir: str | None = None) -> dict | None:
    from repro.launch import hlo as hlo_util

    d = dryrun_dir or DRYRUN_DIR
    path = os.path.join(d, "hlo", f"{arch}__{shape_name}__{mesh}.txt.gz")
    if not os.path.exists(path):
        return None
    with gzip.open(path, "rt") as f:
        return hlo_util.walk_stats(f.read())


def roofline_row(arch: str, shape_name: str, mesh: str = "single",
                 dryrun_dir: str | None = None) -> RooflineRow | None:
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = load_cell(arch, shape_name, mesh, dryrun_dir)
    if rec is None or rec.get("status") != "ok":
        return None
    stats = cell_hlo_stats(arch, shape_name, mesh, dryrun_dir)
    if stats is None:
        return None
    chips = 256 if mesh == "single" else 512
    flops_dev = stats["flops_scaled"]
    coll_dev = stats["collective_bytes_scaled"]
    mem_dev = hbm_bytes(cfg, shape)
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    terms = {
        "compute": flops_dev / PEAK_FLOPS,
        "memory": mem_dev / HBM_BW,
        "collective": coll_dev / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    return RooflineRow(
        arch=arch, shape=shape_name, kind=shape.kind,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dominant,
        model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
    )
