"""Co-location day-cycle A/B (paper §1/§2.3, Fig. 2 headline) + scale sweep.

Runs one full simulated day on the Table 3 mix through the event-driven
co-location engine twice — the topology-aware fused ``imp_batched`` engine
vs the topology-unaware ``godel`` baseline, SAME seeded arrival stream —
and writes ``BENCH_colocation.json`` at the repo root:

* ``uplift``            — scheduled-performance-integral uplift of the
  aware engine over the baseline (the paper reports +55% for the
  preemption-scheduled slice; ``preemptor_uplift`` is that slice here);
* per-engine day totals (hit rate, preemption/requeue counts,
  requeue-success rate, offline goodput);
* ``plan_p50_us_per_hour`` / ``compiled_per_hour`` — the per-hour P50 plan
  dispatch latency of each engine plus the `CompileWatch` compile count
  per hour (the CI latency gate skips compile-polluted hours);
* ``scale`` — the O(delta) host-loop sweep: one 24-hour day per size in
  `SIZES` on ``engine="auto"`` (``imp_batched`` below 4096 nodes,
  ``imp_sharded`` above), recording events/sec and wall clock, with the
  pre-O(delta) ``legacy_loop`` run at `PARITY_SIZES` for the bit-exact
  day-metric parity flags and the events/sec ratio baseline.  Each day
  runs in a subprocess with an 8-device host platform so the sharded
  engine gets a real mesh.

``benchmarks.check_colocation_regression`` gates CI on this file.

Run: ``PYTHONPATH=src python -m benchmarks.bench_colocation``
(``--nodes/--hours/--seed`` override the A/B protocol — overridden runs
print but do NOT rewrite the committed JSON; ``--skip-scale`` omits the
sweep).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

from .common import FULL, emit

BENCH_JSON = Path(__file__).parent.parent / "BENCH_colocation.json"

ENGINES = ("imp_batched", "godel")

# ---- O(delta) event-loop scale sweep -------------------------------------
#: day-cycle sizes for the O(delta) loop on ``engine="auto"``
SIZES = (24, 128, 1024, 10240)
#: non-BENCH_FULL protocol: one small size, short horizon (CI smoke only
#: proves the subprocess path + parity; the committed block is full)
SMALL_SIZES = (24,)
SMALL_HOURS = 6.0
#: sizes where the legacy O(N)-per-event loop ALSO runs a full day — the
#: bit-exact parity check and the events/sec denominator
PARITY_SIZES = (24, 128)
#: the acceptance ratio compares the O(delta) loop at this size...
ODELTA_REF_NODES = 1024
#: ...against the legacy loop at this size (where it still terminates in
#: reasonable wall clock)
LEGACY_REF_NODES = 128
#: committed-run wall-clock budget for the 10240-node day (seconds) —
#: the committed run took ~97 min on a single-core host (the 1M-event
#: stream is host-loop-cheap; the wall is ~450k sharded plan dispatches)
SCALE_BUDGET_S = 7200.0
DEVICES = 8
_CHILD_FLAG = "--scale-child"
_MARK = "COLOCATION_SCALE_JSON:"


def day_config(full: bool = FULL, num_nodes: int | None = None,
               horizon_hours: float = 24.0, seed: int = 0,
               engine: str | None = None, legacy_loop: bool = False):
    from repro.core.colocation import ColocationConfig

    kwargs = {} if engine is None else {"engine": engine}
    return ColocationConfig(
        num_nodes=num_nodes if num_nodes is not None else (41 if full else 24),
        seed=seed, horizon_hours=horizon_hours, warmup=True,
        legacy_loop=legacy_loop, **kwargs)


def report_payload(rep) -> dict:
    return {
        "scheduled_perf": rep.scheduled_perf,
        "preemptor_perf": rep.preemptor_perf,
        "offline_goodput": rep.offline_goodput,
        "hit_rate": rep.hit_rate,
        "hits": rep.hits,
        "preemptions": rep.preemptions,
        "placements": rep.placements,
        "failures": rep.failures,
        "requeued": rep.requeued,
        "requeue_replanned": rep.requeue_replanned,
        "requeue_success_rate": rep.requeue_success_rate,
        "plan_p50_us": rep.plan_p50_us,
        "plan_p50_us_per_hour": [r.plan_p50_us for r in rep.hours],
        # hours whose plan latencies paid cold-jit compile time
        # (`simulator.CompileWatch`); the CI latency gate excludes them
        "compiled_per_hour": [r.compiled_n for r in rep.hours],
    }


# ---------------------------------------------------------------------------
# scale-sweep child: ONE day cycle under the forced 8-device host platform
# ---------------------------------------------------------------------------

def _scale_day(nodes: int, hours: float, seed: int, legacy: bool):
    from repro.core.colocation import ColocationSim, default_policies

    cfg = day_config(num_nodes=nodes, horizon_hours=hours, seed=seed,
                     engine="auto", legacy_loop=legacy)
    sim = ColocationSim(cfg, policies=default_policies(cfg))
    t0 = time.perf_counter()
    rep = sim.run()
    wall = time.perf_counter() - t0
    return sim, rep, wall


def _child_main(args: argparse.Namespace) -> None:
    sim, rep, wall = _scale_day(args.nodes, args.hours, args.seed,
                                args.legacy)
    print(_MARK + json.dumps({
        "nodes": args.nodes,
        "loop": "legacy" if args.legacy else "odelta",
        "engine": sim.sched.engine,
        "horizon_hours": args.hours,
        "seed": args.seed,
        "events": sim.events_processed,
        "wall_s": wall,
        "events_per_sec": sim.events_processed / wall if wall else 0.0,
        # full day metrics only at the (small) parity sizes — the parent
        # compares legacy vs O(delta) dicts whole; both sides go through
        # one json round-trip, so float equality is preserved exactly
        "key_metrics": (rep.key_metrics()
                        if args.nodes in PARITY_SIZES else None),
    }))


def _spawn_scale_day(nodes: int, hours: float, seed: int,
                     legacy: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={DEVICES}"
                        ).strip()
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.bench_colocation", _CHILD_FLAG,
           "--nodes", str(nodes), "--hours", str(hours), "--seed", str(seed)]
    if legacy:
        cmd.append("--legacy")
    proc = subprocess.run(cmd, cwd=BENCH_JSON.parent, env=env,
                          capture_output=True, text=True,
                          timeout=SCALE_BUDGET_S * 1.5)
    if proc.returncode != 0:
        raise RuntimeError(f"scale child failed ({proc.returncode}) at "
                           f"n={nodes} legacy={legacy}:\n"
                           f"{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"no scale result in child output:\n"
                       f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def scale_sweep(full: bool = FULL, hours: float | None = None,
                seed: int = 0) -> dict:
    """One day per size on the O(delta) loop (+ legacy at `PARITY_SIZES`);
    returns the ``scale`` block for ``BENCH_colocation.json``."""
    sizes = SIZES if full else SMALL_SIZES
    if hours is None:
        hours = 24.0 if full else SMALL_HOURS
    rows: list[dict] = []
    parity: dict[str, bool] = {}
    km: dict[tuple[int, str], dict | None] = {}
    for n in sizes:
        for legacy in ((False, True) if n in PARITY_SIZES else (False,)):
            row = _spawn_scale_day(n, hours, seed, legacy)
            km[(n, row["loop"])] = row.pop("key_metrics")
            rows.append(row)
            emit(f"colocation_scale_{n}_{row['loop']}", 0.0,
                 f"engine={row['engine']} events={row['events']} "
                 f"wall={row['wall_s']:.1f}s "
                 f"ev/s={row['events_per_sec']:.0f}")
    for n in sizes:
        if n in PARITY_SIZES:
            parity[str(n)] = km[(n, "odelta")] == km[(n, "legacy")]
            emit(f"colocation_scale_{n}_parity", 0.0,
                 "bit-exact" if parity[str(n)] else "DIVERGED")

    def _evps(nodes: int, loop: str) -> float:
        for row in rows:
            if row["nodes"] == nodes and row["loop"] == loop:
                return row["events_per_sec"]
        return 0.0

    od_ref_n = ODELTA_REF_NODES if full else sizes[-1]
    lg_ref_n = LEGACY_REF_NODES if full else sizes[-1]
    legacy_ref = _evps(lg_ref_n, "legacy")
    odelta_ref = _evps(od_ref_n, "odelta")
    ratio = odelta_ref / legacy_ref if legacy_ref else 0.0
    emit("colocation_scale_evps_ratio", 0.0,
         f"odelta@{od_ref_n}/legacy@{lg_ref_n}={ratio:.1f}x")
    return {
        "protocol": "full" if full else "small",
        "engine": "auto",
        "devices": DEVICES,
        "horizon_hours": hours,
        "seed": seed,
        "sizes": list(sizes),
        "parity_sizes": [n for n in sizes if n in PARITY_SIZES],
        "rows": rows,
        "parity": parity,
        "evps_ratio": ratio,
        "evps_ratio_nodes": [od_ref_n, lg_ref_n],
        "budget_s": SCALE_BUDGET_S,
    }


# ---------------------------------------------------------------------------
# the A/B + sweep driver
# ---------------------------------------------------------------------------

def run(full: bool = FULL, write: bool = True,
        num_nodes: int | None = None, horizon_hours: float = 24.0,
        seed: int = 0, skip_scale: bool = False) -> dict:
    from repro.core.colocation import compare_day_cycle

    cfg = day_config(full, num_nodes=num_nodes,
                     horizon_hours=horizon_hours, seed=seed)
    ab = compare_day_cycle(cfg, engines=ENGINES)
    payload = {
        "num_nodes": cfg.num_nodes,
        "seed": cfg.seed,
        "horizon_hours": cfg.horizon_hours,
        "uplift": ab["uplift"],
        "preemptor_uplift": ab["preemptor_uplift"],
        "goodput_uplift": ab["goodput_uplift"],
        "engines": {name: report_payload(rep)
                    for name, rep in ab["reports"].items()},
    }
    if not skip_scale:
        payload["scale"] = scale_sweep(full)
    if write:
        doc = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else {}
        if skip_scale and "scale" in doc:
            payload["scale"] = doc["scale"]   # keep the committed sweep
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    aware, base = (payload["engines"][e] for e in ENGINES)
    emit("colocation_uplift", 0.0,
         f"scheduled_perf +{payload['uplift'] * 100:.1f}% "
         f"preemptor +{payload['preemptor_uplift'] * 100:.1f}%")
    emit("colocation_aware", aware["plan_p50_us"],
         f"perf={aware['scheduled_perf']:.0f} hit={aware['hit_rate']:.2f} "
         f"requeue={aware['requeue_replanned']}/{aware['requeued']}")
    emit("colocation_baseline", base["plan_p50_us"],
         f"perf={base['scheduled_perf']:.0f} hit={base['hit_rate']:.2f} "
         f"requeue={base['requeue_replanned']}/{base['requeued']}")
    return payload


def _parse_args(argv: list[str]) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="benchmarks.bench_colocation",
        description="Co-location day-cycle A/B + O(delta) scale sweep")
    ap.add_argument("--nodes", type=int, default=None,
                    help="cluster size override (default 24, BENCH_FULL=1: "
                         "41); overridden runs don't rewrite BENCH JSON")
    ap.add_argument("--hours", type=float, default=24.0,
                    help="day-cycle horizon in simulated hours")
    ap.add_argument("--seed", type=int, default=0,
                    help="arrival-stream / placement seed")
    ap.add_argument("--skip-scale", action="store_true",
                    help="skip the O(delta) scale sweep")
    ap.add_argument(_CHILD_FLAG, action="store_true",
                    help=argparse.SUPPRESS)   # internal: one sweep day
    ap.add_argument("--legacy", action="store_true",
                    help=argparse.SUPPRESS)   # child-only: legacy_loop day
    return ap.parse_args(argv)


def main(argv: list[str] | None = None) -> None:
    args = _parse_args(sys.argv[1:] if argv is None else argv)
    if args.scale_child:
        if args.nodes is None:
            raise SystemExit(f"{_CHILD_FLAG} requires --nodes")
        _child_main(args)
        return
    overridden = (args.nodes is not None or args.hours != 24.0
                  or args.seed != 0)
    run(num_nodes=args.nodes, horizon_hours=args.hours, seed=args.seed,
        write=not overridden, skip_scale=args.skip_scale)


if __name__ == "__main__":
    main()
