"""Co-location day-cycle A/B (paper §1/§2.3, Fig. 2 headline).

Runs one full simulated day on the Table 3 mix through the event-driven
co-location engine twice — the topology-aware fused ``imp_batched`` engine
vs the topology-unaware ``godel`` baseline, SAME seeded arrival stream —
and writes ``BENCH_colocation.json`` at the repo root:

* ``uplift``            — scheduled-performance-integral uplift of the
  aware engine over the baseline (the paper reports +55% for the
  preemption-scheduled slice; ``preemptor_uplift`` is that slice here);
* per-engine day totals (hit rate, preemption/requeue counts,
  requeue-success rate, offline goodput);
* ``plan_p50_us_per_hour`` — the per-hour P50 plan dispatch latency of the
  aware engine (the long-horizon workload that amortizes the persistent
  batch session and the device-resident state across thousands of plans).

``benchmarks.check_colocation_regression`` gates CI on this file.

Run: ``PYTHONPATH=src python -m benchmarks.bench_colocation``
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.colocation import ColocationConfig, compare_day_cycle

from .common import FULL, emit

BENCH_JSON = Path(__file__).parent.parent / "BENCH_colocation.json"

ENGINES = ("imp_batched", "godel")


def day_config(full: bool = FULL, num_nodes: int | None = None,
               horizon_hours: float = 24.0, seed: int = 0) -> ColocationConfig:
    return ColocationConfig(
        num_nodes=num_nodes if num_nodes is not None else (41 if full else 24),
        seed=seed, horizon_hours=horizon_hours, warmup=True)


def report_payload(rep) -> dict:
    return {
        "scheduled_perf": rep.scheduled_perf,
        "preemptor_perf": rep.preemptor_perf,
        "offline_goodput": rep.offline_goodput,
        "hit_rate": rep.hit_rate,
        "hits": rep.hits,
        "preemptions": rep.preemptions,
        "placements": rep.placements,
        "failures": rep.failures,
        "requeued": rep.requeued,
        "requeue_replanned": rep.requeue_replanned,
        "requeue_success_rate": rep.requeue_success_rate,
        "plan_p50_us": rep.plan_p50_us,
        "plan_p50_us_per_hour": [r.plan_p50_us for r in rep.hours],
    }


def run(full: bool = FULL, write: bool = True) -> dict:
    cfg = day_config(full)
    ab = compare_day_cycle(cfg, engines=ENGINES)
    payload = {
        "num_nodes": cfg.num_nodes,
        "seed": cfg.seed,
        "horizon_hours": cfg.horizon_hours,
        "uplift": ab["uplift"],
        "preemptor_uplift": ab["preemptor_uplift"],
        "goodput_uplift": ab["goodput_uplift"],
        "engines": {name: report_payload(rep)
                    for name, rep in ab["reports"].items()},
    }
    if write:
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    aware, base = (payload["engines"][e] for e in ENGINES)
    emit("colocation_uplift", 0.0,
         f"scheduled_perf +{payload['uplift'] * 100:.1f}% "
         f"preemptor +{payload['preemptor_uplift'] * 100:.1f}%")
    emit("colocation_aware", aware["plan_p50_us"],
         f"perf={aware['scheduled_perf']:.0f} hit={aware['hit_rate']:.2f} "
         f"requeue={aware['requeue_replanned']}/{aware['requeued']}")
    emit("colocation_baseline", base["plan_p50_us"],
         f"perf={base['scheduled_perf']:.0f} hit={base['hit_rate']:.2f} "
         f"requeue={base['requeue_replanned']}/{base['requeued']}")
    return payload


if __name__ == "__main__":
    run()
